"""Drift-stable summary of the design_space.json CI artifact.

    python tools/design_space_summary.py experiments/dryrun/design_space.json

Extracts ONLY the discrete decisions — winner labels, crossover/frontier
counts, feasibility flags — and none of the floating-point metrics, so the
output is stable across JAX versions and platforms unless a design-space
WINNER actually changes.  CI regenerates the artifact on every push and
diffs this summary against the checked-in golden
(``experiments/golden/design_space_summary.json``); drift fails the job.

Regenerate the golden after an intentional frontier change:

    PYTHONPATH=src python examples/memsys_explorer.py --bridge
    python tools/design_space_summary.py \
        experiments/dryrun/design_space.json \
        > experiments/golden/design_space_summary.json
"""
import json
import sys


def summarize(ds: dict) -> dict:
    out = {
        "keys": ds.get("keys", []),
        "objective": ds.get("objective"),
        "shorelines": ds.get("shorelines", []),
        "workloads": {},
    }
    for name in sorted(ds.get("workloads", {})):
        w = ds["workloads"][name]
        out["workloads"][name] = {
            "mix": w["mix"],
            "best": w["best"],
            "feasible": w["feasible"],
            "crossover_count": len(w["crossovers"]),
            "crossover_winners": [c["best"] for c in w["crossovers"]],
            "shoreline_frontier": w["shoreline_frontier"],
            "shoreline_sensitive": w["shoreline_sensitive"],
        }
    jf = ds.get("joint_frontier")
    if jf is not None:
        pairs = sorted({(r["analytic_best"], r["simulated_best"])
                        for r in jf["disagreement_regions"]})
        out["joint_frontier"] = {
            "keys": jf["keys"],
            "disagreement_region_count": len(jf["disagreement_regions"]),
            "disagreement_pairs": [list(p) for p in pairs],
            "disagreeing_backlogs": sorted(
                {r["backlog"] for r in jf["disagreement_regions"]}),
        }
        sbs = jf.get("sim_bandwidth_gbs")
        if sbs is not None:
            # the folded PHY-absolute subsection: winner labels only
            # (peak GB/s floats excluded by design)
            out["joint_frontier"]["sim_bandwidth_gbs"] = {
                "phys": sbs["phys"],
                "best_protocol_by_phy": sbs["best_protocol_by_phy"],
                "regime_winners_by_phy_backlog": {
                    phy: {bl: [r["best"] for r in regs]
                          for bl, regs in sorted(by_bl.items())}
                    for phy, by_bl in sorted(
                        sbs["regimes_by_phy_backlog"].items())},
            }
    pf = ds.get("phy_frontier")
    if pf is not None:
        out["phy_frontier"] = {
            "phys": pf["phys"],
            "best_approach_by_phy": pf["best_approach_by_phy"],
            "regime_winners_by_phy": {
                phy: [r["best"] for r in regs]
                for phy, regs in sorted(pf["regimes_by_phy"].items())},
        }
    spf = ds.get("sim_phy_frontier")
    if spf is not None:
        # winner labels only — adaptive convergence cycles and absolute
        # GB/s are floats/timing-ish and excluded by design
        out["sim_phy_frontier"] = {
            "phys": spf["phys"],
            "best_protocol_by_phy": spf["best_protocol_by_phy"],
            "shallow_queue_disagrees": spf["shallow_queue_disagrees"],
            "regime_winners_by_phy_backlog": {
                phy: {bl: [r["best"] for r in regs]
                      for bl, regs in sorted(by_bl.items())}
                for phy, by_bl in sorted(
                    spf["regimes_by_phy_backlog"].items())},
        }
    sf = ds.get("serving_frontier")
    if sf is not None:
        # winner labels per (model, QPS) only — delivered GB/s, trace
        # phase floats, and telemetry are excluded by design
        out["serving_frontier"] = {
            "models": sf["models"],
            "phy": sf["phy"],
            "arrival": sf["arrival"],
            "winner_by_model_qps": {
                m: dict(sorted(w.items()))
                for m, w in sorted(sf["winner_by_model_qps"].items())},
            "qps_sensitive": dict(sorted(sf["qps_sensitive"].items())),
        }
    return out


def main() -> None:
    if len(sys.argv) != 2:
        sys.exit(f"usage: {sys.argv[0]} <design_space.json>")
    with open(sys.argv[1]) as f:
        ds = json.load(f)
    json.dump(summarize(ds), sys.stdout, indent=1, sort_keys=True)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
